"""Pure-jnp oracle for the GPQ (grouped-partial-sum quantized) matmul.

Independent of core/matmul.py's scan formulation on purpose: this is the
vectorized "textbook" statement of the macro semantics used to
cross-validate both the behavioral model and the Pallas kernel.

  pmac[m, g, b, n] = sum_{k in group g} x[m, k] * bit_b(w[k, n])
  code             = clip(floor(pmac / step), 0, 2**adc_bits - 1)
  y[m, n]          = sum_{g, b} sign_b * step * code

Noiseless by definition (the kernel is the production path; hardware-
error Monte-Carlo runs through core.matmul.cim_matmul_int).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.params import CIMConfig
from repro.core.quant import bitslice_weights, plane_signs


def cim_matmul_ref(
    x_codes: jax.Array, w_codes: jax.Array, cfg: CIMConfig
) -> jax.Array:
    """[M, K] x [K, N] -> [M, N] float32, macro semantics, vectorized."""
    m, k = x_codes.shape
    k2, n = w_codes.shape
    assert k == k2
    rows = cfg.rows_active
    b = cfg.weight_bits
    k_pad = -(-k // rows) * rows

    x = jnp.pad(x_codes.astype(jnp.float32), ((0, 0), (0, k_pad - k)))
    w = jnp.pad(w_codes.astype(jnp.int32), ((0, k_pad - k), (0, 0)))
    g = k_pad // rows

    planes = bitslice_weights(w, b).astype(jnp.float32)  # [B, Kp, N]
    planes = planes.reshape(b, g, rows, n)
    xg = x.reshape(m, g, rows)

    pmac = jnp.einsum("mgr,bgrn->mgbn", xg, planes)
    code = jnp.clip(
        jnp.floor(pmac / cfg.adc_step), 0, cfg.adc_codes - 1
    )
    signs = plane_signs(b).astype(jnp.float32)
    return jnp.einsum("mgbn,b->mn", code * cfg.adc_step, signs)
