"""Per-(arch, variant, shape-cell) kernel autotuning with a JSON cache.

The dispatch heuristics pick a safe default; this module replaces them
with *measured* winners: :func:`sweep_shape` times every registered
backend (and, for Pallas, every candidate block size) of one variant
at one representative shape, :func:`autotune` runs the sweep over a
shape/variant grid, and the winners persist to a JSON cache under
``results/autotune/<arch>.json`` that ``dispatch`` consults before its
heuristics — so a tuned deployment keeps its per-shape choices across
processes with a deterministic re-load path (no re-timing at serve
time).

Cache file format (version 1)::

    {
      "version": 1,
      "arch": "cpu",
      "sweep_version": 3,
      "entries": {
        "p8t/m8_k1024_n1024":  {"backend": "ref", "block": null,
                                "us": 812.4, "swept_at": 3},
        "p8t/m128_k1024_n1024": {"backend": "pallas",
                                 "block": [128, 128, 128],
                                 "us": 95.1, "swept_at": 2}
      }
    }

Keys are ``<variant>/m<cell>_k<cell>_n<cell>`` over the power-of-two
cells of :func:`dispatch.shape_cell`; ``block`` is the pinned Pallas
tiling (null for jnp backends). Entries are written sorted, so the
same sweep produces byte-identical files (round-trip determinism is
property-tested).

``sweep_version`` is a monotone counter bumped by every merging
:func:`autotune` run, and each entry records the ``swept_at`` version
that last measured it — NOT a wall-clock stamp (artifact determinism,
CIM201), but enough for :func:`stale_entries` to flag cells a partial
re-sweep left behind (surfaced by ``repro.sweep``'s ``--analyze``
autotune renderer).

Timing is injectable (``measure=``) so tests pin winners with a
deterministic proxy; the default measures best-of-``reps`` wall time
of a jitted call. Candidates that fail to trace/execute at the shape
(e.g. a depth-guarded Pallas kernel) are skipped, never winners.
"""

from __future__ import annotations

import dataclasses
import json
import logging
import os
import pathlib
import time
from typing import Any, Callable, Iterable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.params import CIMConfig
from repro.core.pipeline import MacroSpec, as_spec
from repro.kernels import dispatch

CACHE_VERSION = 1

logger = logging.getLogger(__name__)

# Pallas tiling candidates swept per shape (bk is clamped to a multiple
# of rows_active by the dispatch adapter).
PALLAS_BLOCKS: tuple[tuple[int, int, int], ...] = (
    (128, 128, 128),
    (64, 128, 128),
    (32, 64, 128),
)

# Small-bm candidates for the decode shapes (see decode_blocks).
DECODE_BMS: tuple[int, ...] = (1, 8, 16)


def decode_blocks(
    rows: int, m: int | None = None, *, bn: int = 128
) -> tuple[tuple[int, int, int], ...]:
    """Decode-shape Pallas tiling candidates.

    The default 128-row M tiles pad an m=1 decode step to 128 rows and
    burn 128x the FLOPs; these candidates pair small bm values
    (``DECODE_BMS``, dropped above the next power of two of ``m`` so
    an m=1 sweep times only bm=1) with bk values aligned to the
    calibration's ``rows_active`` group (the kernel requires
    rows | bk, and a rows-aligned bk avoids the dispatch adapter's
    round-down losing contraction depth for non-power-of-two rows).
    """
    cap = None
    if m is not None:
        cap = 1
        while cap < m and cap < max(DECODE_BMS):
            cap *= 2
    bks = sorted({max(rows, 128 - 128 % rows), 8 * rows})
    return tuple(
        (bm, bn, bk)
        for bm in DECODE_BMS
        if cap is None or bm <= cap
        for bk in bks
    )

Candidate = tuple[str, tuple[int, int, int] | None]
# measure(candidate, run) -> seconds for one call; `run` executes the
# (already warmed/compiled) candidate once, blocking on the result.
MeasureFn = Callable[[Candidate, Callable[[], Any]], float]


def default_cache_dir() -> pathlib.Path:
    """results/autotune under the repo root (env-overridable)."""
    env = os.environ.get("REPRO_AUTOTUNE_DIR")
    if env:
        return pathlib.Path(env)
    return (
        pathlib.Path(__file__).resolve().parents[3] / "results" / "autotune"
    )


def cache_path(arch: str) -> pathlib.Path:
    return default_cache_dir() / f"{arch}.json"


@dataclasses.dataclass(frozen=True)
class Winner:
    """The pinned choice for one (variant, shape cell).

    ``swept_at`` is the cache's ``sweep_version`` when this entry was
    last measured (0 = predates versioned sweeps); it is bookkeeping
    for staleness reporting and does not affect dispatch.
    """

    backend: str
    block: tuple[int, int, int] | None
    us: float
    swept_at: int = 0

    def to_json(self) -> dict:
        return {
            "backend": self.backend,
            "block": list(self.block) if self.block else None,
            "us": self.us,
            "swept_at": self.swept_at,
        }

    @classmethod
    def from_json(cls, d: Mapping) -> "Winner":
        block = d.get("block")
        return cls(
            backend=d["backend"],
            block=tuple(block) if block else None,
            us=float(d.get("us", 0.0)),
            swept_at=int(d.get("swept_at", 0)),
        )


def cell_id(variant: str, cell: tuple[int, int, int]) -> str:
    return f"{variant}/m{cell[0]}_k{cell[1]}_n{cell[2]}"


@dataclasses.dataclass
class TuningCache:
    """The per-arch winner table, JSON round-trippable.

    ``sweep_version`` counts merging :func:`autotune` runs; entries
    whose ``swept_at`` lags it were inherited from an earlier sweep
    (see :func:`stale_entries`).
    """

    arch: str
    entries: dict[str, Winner] = dataclasses.field(default_factory=dict)
    sweep_version: int = 0

    def lookup(
        self, variant: str, cell: tuple[int, int, int]
    ) -> Winner | None:
        return self.entries.get(cell_id(variant, cell))

    def put(
        self, variant: str, cell: tuple[int, int, int], winner: Winner
    ) -> None:
        self.entries[cell_id(variant, cell)] = winner

    def to_json(self) -> dict:
        return {
            "version": CACHE_VERSION,
            "arch": self.arch,
            "sweep_version": self.sweep_version,
            "entries": {
                k: self.entries[k].to_json() for k in sorted(self.entries)
            },
        }

    @classmethod
    def from_json(cls, d: Mapping) -> "TuningCache":
        if d.get("version") != CACHE_VERSION:
            raise ValueError(
                f"tuning cache version {d.get('version')} != "
                f"{CACHE_VERSION}; re-run kernels.autotune.autotune"
            )
        return cls(
            arch=d.get("arch", "unknown"),
            entries={
                k: Winner.from_json(v) for k, v in d["entries"].items()
            },
            sweep_version=int(d.get("sweep_version", 0)),
        )

    def save(self, path: pathlib.Path | str | None = None) -> pathlib.Path:
        path = pathlib.Path(path) if path else cache_path(self.arch)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_json(), indent=1, sort_keys=True))
        return path

    @classmethod
    def load(
        cls,
        arch: str | None = None,
        path: pathlib.Path | str | None = None,
    ) -> "TuningCache | None":
        """Deterministic re-load: None when no cache was ever written."""
        path = pathlib.Path(path) if path else cache_path(
            arch or jax.default_backend()
        )
        if not path.exists():
            return None
        return cls.from_json(json.loads(path.read_text()))


# ---------------------------------------------------------------------------
# The active cache dispatch consults
# ---------------------------------------------------------------------------

_active: TuningCache | None = None
_loaded = False


def active_cache() -> TuningCache | None:
    """The cache dispatch consults; lazily loaded from results/ once.

    The file is an optional *hint*: a stale-version or corrupt cache
    must degrade to the dispatch heuristics (with a one-time warning),
    never brick serving — and a cache that was simply never written
    for this arch (only ``cpu.json`` ships today) degrades the same
    way, with a one-time log line naming the missing file. Explicit
    ``TuningCache.load`` calls keep their strict errors.
    """
    global _active, _loaded
    if not _loaded:
        arch = jax.default_backend()
        try:
            _active = TuningCache.load()
            if _active is None:
                logger.info(
                    "no tuning cache for arch '%s' (%s missing): "
                    "kernel dispatch falls back to the deterministic "
                    "heuristics; run kernels.autotune.autotune (or a "
                    "configs/sweeps/autotune_*.json sweep) to pin "
                    "measured winners",
                    arch, cache_path(arch),
                )
        except Exception as e:  # noqa: BLE001 - degrade, don't brick
            import warnings

            warnings.warn(
                f"ignoring unreadable tuning cache "
                f"({cache_path(arch)}): {e}; "
                "re-run kernels.autotune.autotune to regenerate",
                stacklevel=2,
            )
            _active = None
        _loaded = True
    return _active


def set_active(cache: TuningCache | None) -> None:
    global _active, _loaded
    _active, _loaded = cache, True


def clear_active() -> None:
    """Disable tuned dispatch for this process (heuristics only)."""
    set_active(None)


def reload_active() -> TuningCache | None:
    """Force a re-read from the default cache path."""
    global _loaded
    _loaded = False
    return active_cache()


def lookup(variant: str, cell: tuple[int, int, int]) -> Winner | None:
    cache = active_cache()
    return None if cache is None else cache.lookup(variant, cell)


def stale_entries(cache: TuningCache) -> tuple[str, ...]:
    """Entry ids whose winner predates the cache's latest sweep.

    A partial re-sweep (``autotune(merge=True)`` over a subset of
    cells) bumps ``sweep_version`` and stamps only the swept cells;
    everything it inherited keeps its old ``swept_at`` and shows up
    here — including ``swept_at=0`` entries from pre-versioning
    caches, which is exactly the single-entry-cache staleness this
    reporting exists to surface.
    """
    return tuple(sorted(
        k for k, w in cache.entries.items()
        if w.swept_at < cache.sweep_version
    ))


# ---------------------------------------------------------------------------
# Sweeping
# ---------------------------------------------------------------------------


def cache_from_records(
    arch: str, records: Iterable[Mapping],
    prev: TuningCache | None = None,
) -> TuningCache:
    """A TuningCache from measured-winner records (the sweep harness).

    Each record carries ``variant``, ``cell`` ([m, k, n] tuning cell),
    ``backend``, ``block`` and ``us``. Later records win a shared
    cell, matching :func:`autotune`'s last-sweep-wins merge. ``prev``
    (e.g. the committed per-arch cache) seeds inherited entries at
    their old ``swept_at``; the fresh records stamp the bumped
    ``sweep_version``, so :func:`stale_entries` of the result is the
    not-re-swept remainder.
    """
    cache = TuningCache(arch=arch)
    if prev is not None:
        cache.entries.update(prev.entries)
        cache.sweep_version = prev.sweep_version
    cache.sweep_version += 1
    for r in records:
        cache.put(
            r["variant"], tuple(int(d) for d in r["cell"]),
            Winner(
                backend=r["backend"],
                block=tuple(r["block"]) if r.get("block") else None,
                us=float(r.get("us", 0.0)),
                swept_at=cache.sweep_version,
            ),
        )
    return cache


def default_candidates(
    variant: str,
    *,
    blocks: Sequence[tuple[int, int, int]] = PALLAS_BLOCKS,
    include_pallas: bool | None = None,
    rows: int | None = None,
    m: int | None = None,
) -> tuple[Candidate, ...]:
    """Candidate (backend, block) pairs for one variant, stable order.

    ``include_pallas`` defaults to native-lowering only (TPU): in
    interpret mode the kernel is a correctness vehicle, and timing it
    would never pin it anyway — skipping keeps sweeps fast on CPU.
    Pass True to sweep it regardless. With ``rows`` (the operating
    point's ``rows_active``) the Pallas block list extends with the
    :func:`decode_blocks` small-bm / rows-aligned-bk candidates for
    the sweep's ``m``.
    """
    if include_pallas is None:
        include_pallas = jax.default_backend() == "tpu"
    if rows is not None:
        seen = set(blocks)
        blocks = tuple(blocks) + tuple(
            b for b in decode_blocks(rows, m) if b not in seen
        )
    cands: list[Candidate] = []
    for backend in dispatch.backends_for(variant):
        if dispatch.lookup(variant, backend) is None:
            continue
        if backend == "pallas":
            if include_pallas:
                cands.extend(("pallas", b) for b in blocks)
        else:
            cands.append((backend, None))
    return tuple(cands)


def _wall_measure(reps: int) -> MeasureFn:
    def measure(candidate: Candidate, run: Callable[[], Any]) -> float:
        del candidate
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            run()
            best = min(best, time.perf_counter() - t0)
        return best

    return measure


def sweep_shape(
    variant: str,
    spec: CIMConfig | MacroSpec | None,
    m: int,
    k: int,
    n: int,
    *,
    candidates: Sequence[Candidate] | None = None,
    measure: MeasureFn | None = None,
    reps: int = 3,
    seed: int = 0,
) -> Winner:
    """Time every candidate at one shape; return the pinned winner.

    Deterministic given a deterministic ``measure``: candidates are
    evaluated in their stable enumeration order and ties keep the
    earlier candidate.

    Every candidate is timed against the operands a *served* plan
    provides — narrow integer codes plus the planned packed planes and
    spread-slot tensors — so winners reflect the traffic the serving
    path actually pays (and plan-dependent backends like "slots" are
    sweepable at all; infeasible ones skip, never win).
    """
    spec = as_spec(spec) if spec is not None else MacroSpec()
    spec = spec.replace(noisy=False)
    if candidates is None:
        candidates = default_candidates(
            variant, rows=spec.rows_active, m=m
        )
    if measure is None:
        measure = _wall_measure(reps)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(0, spec.act_levels, (m, k)), jnp.int32)
    lo = -(1 << (spec.weight_bits - 1))
    hi = 1 << (spec.weight_bits - 1)
    cdtype = jnp.int8 if spec.weight_bits <= 8 else jnp.int32
    w = jnp.asarray(rng.integers(lo, hi, (k, n)), cdtype)

    from repro.core import engine  # noqa: PLC0415 - lazy, no cycle
    from repro.core import quant  # noqa: PLC0415

    planes = None
    if spec.weight_bits <= 8:
        planes = engine._grouped_planes(
            w.astype(jnp.int32), spec, packed=True
        )
    try:
        slots = quant.spread_slots(
            w.astype(jnp.int32), spec.rows_active, spec.act_bits,
            spec.weight_bits,
        )
    except ValueError:  # infeasible operating point for slot packing
        slots = None

    best: Winner | None = None
    for backend, block in candidates:
        fn = jax.jit(
            lambda xx, ww, pp, ss, _b=backend, _blk=block:
            dispatch.dispatch(
                xx, ww, spec, variant=variant, backend=_b, block=_blk,
                planes=pp, slots=ss,
            )
        )
        try:
            jax.block_until_ready(fn(x, w, planes, slots))
        except Exception:  # noqa: BLE001 - infeasible candidate (depth guard...)
            continue
        secs = float(measure(
            (backend, block),
            lambda: jax.block_until_ready(fn(x, w, planes, slots)),
        ))
        if best is None or secs * 1e6 < best.us:
            best = Winner(backend=backend, block=block, us=secs * 1e6)
    if best is None:
        raise RuntimeError(
            f"no feasible kernel candidate for variant='{variant}' at "
            f"shape ({m}, {k}, {n})"
        )
    return best


def autotune(
    shapes: Iterable[tuple[int, int, int]],
    spec: CIMConfig | MacroSpec | None = None,
    *,
    variants: Sequence[str] = ("p8t", "adder-tree", "cell-adc"),
    arch: str | None = None,
    save: bool = True,
    path: pathlib.Path | str | None = None,
    activate: bool = True,
    merge: bool = True,
    **sweep_kw,
) -> TuningCache:
    """Sweep a (variants x shapes) grid and persist/activate the winners.

    One entry per (variant, shape cell); when several concrete shapes
    fall in one cell the last sweep wins (pass one representative per
    cell). With ``save`` the cache lands at ``results/autotune/`` (or
    ``path``); with ``activate`` it becomes the cache dispatch
    consults in this process. ``merge`` (default) seeds the result
    with the previously persisted entries for this arch, so a partial
    re-sweep updates only the swept cells instead of discarding every
    other pinned winner; pass ``merge=False`` to start clean. Either
    way ``sweep_version`` bumps and the freshly swept cells are
    stamped with it — inherited cells keep their old ``swept_at`` and
    show up in :func:`stale_entries`.
    """
    arch = arch or jax.default_backend()
    shapes = tuple(shapes)  # generators must survive the variant loop
    cache = TuningCache(arch=arch)
    if merge:
        prev = TuningCache.load(arch=arch, path=path)
        if prev is not None:
            cache.entries.update(prev.entries)
            cache.sweep_version = prev.sweep_version
    cache.sweep_version += 1
    for variant in variants:
        for (m, k, n) in shapes:
            cell = dispatch.shape_cell(m, k, n)
            win = sweep_shape(variant, spec, m, k, n, **sweep_kw)
            cache.put(
                variant, cell,
                dataclasses.replace(win, swept_at=cache.sweep_version),
            )
    if save:
        cache.save(path)
    if activate:
        set_active(cache)
    return cache
