"""Unified variant-aware kernel dispatch: one table for every macro matmul.

Before this module, executing a macro variant took three parallel
edits: a tuned backend in ``kernels/ops.py``, a string key in
``core/engine.py``'s backend registry, and a per-variant ``matmul_int``
wired into ``core/variants.py`` / ``core/calibrate.py``. The dispatch
table collapses them into one subsystem: a

    KernelKey(variant, backend, shape_cell, dtype) -> implementation

map that ``engine.execute`` (the behavioral/pallas built-ins), the
calibrated "analog" backend and ``ServeEngine`` all route through.
Adding a macro variant or a device kernel is ONE ``register_kernel``
call (and any variant registered in ``core.variants`` gets its scan
transfer auto-wired — zero calls).

Built-in backends per variant:

  "scan"    the jnp ``lax.scan`` transfer (one group per step). The
            only backend that injects hardware noise; peak memory is
            one group tile, so it is the large-shape default.
  "ref"     the vectorized formulation (kernels.ref): a single fused
            einsum pair. Wins at decode shapes (small M) on CPU/GPU —
            the per-shape choice the autotuner discovers.
  "slots"   the spread-slot formulation (kernels.ref): all bit planes
            packed into exact f32 integer fields so ONE batched dot
            yields every plane pMAC. Needs the plan's precomputed
            ``slots`` operand (grouped at the executing rows_active —
            it cannot be regrouped); the decode-shape (small M)
            bandwidth winner.
  "pallas"  the fused Pallas kernel (kernels.cim_mac); native lowering
            on TPU, interpret mode elsewhere. Noiseless by design
            (production inference path). Consumes a plan's *packed*
            planes directly (flatten-sliced to the [K, N] byte matrix,
            unpacked per tile inside the kernel).

Resolution order when no backend is requested explicitly:

  1. hardware-noise injection (``spec.noisy`` and a key) semantically
     requires the scan transfer — recorded as source="noise";
  2. the autotune cache (``kernels.autotune``): the pinned winner for
     (arch, variant, shape cell), including its block sizes;
  3. heuristics: the variant's Pallas kernel on TPU, else the scan.

An explicit ``backend=`` request is always honored (no silent
fallback — ``record_resolutions`` lets callers and the check.sh guard
assert exactly which implementation ran); an unknown key raises.

An implementation is ``fn(x_codes, w_codes, spec, *, key=None,
planes=None, block=None) -> [M, N] float32`` in integer-domain macro
units — the ``matmul.cim_matmul_int`` contract (plus ``slots=`` for
implementations registered with ``supports_slots``). ``planes``
carries a plan's pre-grouped bit planes (packed planes feed the Pallas
kernels directly; the dispatcher regroups mismatched planes only for
implementations that read them), ``slots`` a plan's spread-slot
operand, ``block`` a (bm, bn, bk) Pallas tiling.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Callable, Iterator

import jax

from repro.core import matmul as matmul_lib
from repro.core import variants as variants_lib
from repro.core.params import CIMConfig
from repro.core.pipeline import MacroSpec, as_spec
from repro.kernels import ref as ref_lib

# fn(x_codes, w_codes, spec, *, key, planes, block) -> [M, N] f32
KernelFn = Callable[..., jax.Array]

# Backend preference order (used by autotune candidate enumeration).
KNOWN_BACKENDS = ("scan", "ref", "slots", "pallas")


@dataclasses.dataclass(frozen=True)
class KernelKey:
    """Registration/lookup key of one kernel implementation.

    ``shape_cell``/``dtype`` of None are wildcards (match any); a
    non-None cell or dtype registers a shape- or dtype-specialized
    kernel that wins over the generic one (most-specific-first lookup).
    """

    variant: str
    backend: str
    shape_cell: tuple[int, int, int] | None = None
    dtype: str | None = None


@dataclasses.dataclass(frozen=True)
class KernelImpl:
    """A registered implementation plus its capability flags."""

    fn: KernelFn
    supports_noise: bool = False
    supports_planes: bool = False
    supports_slots: bool = False
    is_pallas: bool = False


@dataclasses.dataclass(frozen=True)
class Resolution:
    """One dispatch decision (recorded at trace time under jit)."""

    key: KernelKey
    source: str  # "explicit" | "noise" | "tuned" | "heuristic"
    block: tuple[int, int, int] | None = None


_TABLE: dict[KernelKey, KernelImpl] = {}
_LISTENERS: list[Callable[[Resolution], None]] = []


def register_kernel(
    key: KernelKey,
    fn: KernelFn,
    *,
    supports_noise: bool = False,
    supports_planes: bool = False,
    supports_slots: bool = False,
    is_pallas: bool = False,
    overwrite: bool = False,
) -> KernelKey:
    """Register one implementation under a KernelKey. Returns the key."""
    if key in _TABLE and not overwrite:
        raise ValueError(
            f"kernel {key} already registered (overwrite=True to replace)"
        )
    _TABLE[key] = KernelImpl(
        fn=fn,
        supports_noise=supports_noise,
        supports_planes=supports_planes,
        supports_slots=supports_slots,
        is_pallas=is_pallas,
    )
    return key


def kernel_keys() -> tuple[KernelKey, ...]:
    """Every registered key, deterministically ordered."""
    return tuple(sorted(
        _TABLE,
        key=lambda k: (k.variant, k.backend, k.shape_cell or (),
                       k.dtype or ""),
    ))


def backends_for(variant: str) -> tuple[str, ...]:
    """Registered backends of one variant, in preference order."""
    got = {k.backend for k in _TABLE if k.variant == variant}
    if variant in variants_lib.names():
        got.add("scan")  # auto-wired from the MacroVariant registry
    ordered = [b for b in KNOWN_BACKENDS if b in got]
    return tuple(ordered + sorted(got - set(KNOWN_BACKENDS)))


def has_pallas(variant: str) -> bool:
    return any(
        k.variant == variant and _TABLE[k].is_pallas for k in _TABLE
    )


_CELL_CAP = 8192


def shape_cell(m: int, k: int, n: int) -> tuple[int, int, int]:
    """Bucket a concrete (M, K, N) into its tuning cell.

    Each dim rounds up to the next power of two (capped at 8192): the
    autotuner sweeps one representative per cell and the pinned winner
    serves every shape in it — decode steps with 1..8 in-flight tokens
    all land in the m=8 cell, for example.
    """

    def cell(d: int) -> int:
        p = 1
        while p < d and p < _CELL_CAP:
            p *= 2
        return p

    return (cell(m), cell(k), cell(n))


def lookup(
    variant: str,
    backend: str,
    shape_cell: tuple[int, int, int] | None = None,
    dtype: str | None = None,
) -> KernelImpl | None:
    """Most-specific-first table lookup; auto-wires variant scans.

    A "scan" miss for a variant present in the ``core.variants``
    registry is satisfied from ``MacroVariant.matmul_int``, so
    registering a variant is enough to execute it — the dispatch half
    of "one registration instead of three edits". (The auto-wired impl
    is built per lookup, NOT written into the table: a later explicit
    ``register_kernel(KernelKey(v, "scan"), ...)`` must succeed
    regardless of whether a dispatch ran first.)
    """
    for key in (
        KernelKey(variant, backend, shape_cell, dtype),
        KernelKey(variant, backend, shape_cell, None),
        KernelKey(variant, backend, None, dtype),
        KernelKey(variant, backend, None, None),
    ):
        impl = _TABLE.get(key)
        if impl is not None:
            return impl
    if backend == "scan" and variant in variants_lib.names():
        var = variants_lib.get(variant)

        def run(x_codes, w_codes, spec, *, key=None, planes=None,
                block=None, _fn=var.matmul_int):
            del block
            return _fn(x_codes, w_codes, spec, key=key, planes=planes)

        return KernelImpl(
            fn=run, supports_noise=True, supports_planes=True
        )
    return None


@contextlib.contextmanager
def record_resolutions() -> Iterator[list[Resolution]]:
    """Capture every dispatch decision made inside the context.

    Under jit the decision happens at trace time, so a cached
    compilation records nothing — wrap the first (tracing) call. Used
    by the no-silent-fallback guard in benchmarks/kernel_bench.py and
    the routing tests.
    """
    log: list[Resolution] = []
    _LISTENERS.append(log.append)
    try:
        yield log
    finally:
        _LISTENERS.remove(log.append)


def _notify(res: Resolution) -> None:
    for cb in _LISTENERS:
        cb(res)


def _has_backend(variant: str, backend: str) -> bool:
    return any(
        k.variant == variant and k.backend == backend for k in _TABLE
    )


# Largest M for which the heuristic (no tuned pin) takes the slots
# formulation: its weight traffic is M-independent, so it wins the
# bandwidth-bound decode shapes and loses to plain contractions once M
# amortizes the weight reads. The autotune corpus overrides per cell.
_SLOTS_HEURISTIC_MAX_M = 32


def _heuristic_backend(variant: str, planes, slots, m: int) -> str:
    # A plan's spread-slot operand exists exactly for the decode shapes
    # — take it when M is small and no tuned pin says otherwise.
    if (
        slots is not None
        and m <= _SLOTS_HEURISTIC_MAX_M
        and _has_backend(variant, "slots")
    ):
        return "slots"
    # Unpacked pre-grouped planes are a weight-stationary optimization
    # the Pallas kernels don't consume (packed planes they do, via the
    # flatten-slice path) — implicit routing keeps the plan semantics
    # and takes the scan; the autotune cache can still pin pallas.
    if (
        (planes is None or planes.ndim == 3)
        and jax.default_backend() == "tpu"
        and has_pallas(variant)
    ):
        return "pallas"
    return "scan"


def dispatch(
    x_codes: jax.Array,
    w_codes: jax.Array,
    spec: CIMConfig | MacroSpec,
    *,
    variant: str = "p8t",
    backend: str | None = None,
    key: jax.Array | None = None,
    planes: jax.Array | None = None,
    slots: jax.Array | None = None,
    block: tuple[int, int, int] | None = None,
) -> jax.Array:
    """Route one integer-domain macro matmul to its implementation.

    Args:
      x_codes: [M, K] activation codes; w_codes: [K, N] signed weight
        codes (a plan's ``codes`` — any integer dtype).
      spec: the operating point (variant transfer constants).
      variant: macro family name (``core.variants`` registry).
      backend: explicit implementation choice; None = tuned/heuristic.
      key: PRNG key for hardware-noise injection — routes to the scan
        transfer unless the backend was requested explicitly (the
        Pallas/ref formulations are noiseless by design and ignore it).
      planes: plan-grouped bit planes. Forwarded to implementations
        that consume them; a grouping mismatch with the executing
        ``spec.rows_active`` is normalized here (regroup) at trace
        time, ONLY when the chosen implementation actually reads them
        — nothing weight-side runs for kernels that ignore planes.
      slots: plan spread-slot operand (``plan_weights(with_slots=)``).
        Dropped when grouped at a different rows_active (slots cannot
        be regrouped); the "slots" backend requires it.
      block: (bm, bn, bk) Pallas tiling override; defaults to the
        tuned winner's blocks, else (128, 128, 128).
    """
    spec = as_spec(spec)
    m, k = x_codes.shape
    n = w_codes.shape[-1]
    cell = shape_cell(m, k, n)
    dtype = w_codes.dtype.name
    noisy = bool(spec.noisy) and key is not None
    if slots is not None and slots.shape[-2] != spec.rows_active:
        # Grouped for a different row count — the slot fields encode
        # that grouping irreversibly, so the operand is unusable here.
        slots = None

    source = "explicit"
    if backend is None:
        if noisy:
            backend, source = "scan", "noise"
        else:
            from repro.kernels import autotune  # noqa: PLC0415 - cycle-free lazy

            win = autotune.lookup(variant, cell)
            if win is not None:
                backend, source = win.backend, "tuned"
                if block is None:
                    block = win.block
            else:
                backend = _heuristic_backend(variant, planes, slots, m)
                source = "heuristic"

    impl = lookup(variant, backend, cell, dtype)
    if impl is None:
        raise KeyError(
            f"no kernel registered for variant='{variant}' "
            f"backend='{backend}' (cell={cell}, dtype={dtype}); "
            f"registered backends for this variant: "
            f"{backends_for(variant)}"
        )
    _notify(Resolution(
        key=KernelKey(variant, backend, cell, dtype),
        source=source,
        block=block if impl.is_pallas else None,
    ))

    def planes_for(chosen: KernelImpl):
        if not chosen.supports_planes or planes is None:
            return None
        if chosen.is_pallas or planes.shape[-2] == spec.rows_active:
            # The Pallas flatten-slice path recovers the [K, N] byte
            # matrix at ANY grouping — no regroup needed there.
            return planes
        from repro.core import engine  # noqa: PLC0415 - lazy, no cycle

        return engine.regroup_planes(planes, k, spec.rows_active)

    def run(chosen: KernelImpl, blk):
        kwargs: dict[str, Any] = dict(
            key=key if chosen.supports_noise else None,
            planes=planes_for(chosen),
            block=blk,
        )
        if chosen.supports_slots:
            kwargs["slots"] = slots
        return chosen.fn(x_codes, w_codes, spec, **kwargs)

    if source == "explicit" or backend == "scan":
        return run(impl, block)
    try:
        return run(impl, block)
    except ValueError:
        # Implicitly-chosen impl infeasible at this shape/operating
        # point (e.g. the Pallas f32 depth guard, a stale tuned pin):
        # fall back to the always-feasible scan transfer and RECORD it
        # — explicit requests above still raise loudly, which is what
        # the no-silent-fallback guard asserts.
        scan = lookup(variant, "scan", cell, dtype)
        if scan is None:  # kernel-only custom variant: nothing to fall to
            raise
        _notify(Resolution(
            key=KernelKey(variant, "scan", cell, dtype),
            source="guard-fallback",
        ))
        return run(scan, None)


# ---------------------------------------------------------------------------
# Built-in implementations
# ---------------------------------------------------------------------------


def _scan_impl(module, attr: str) -> KernelFn:
    # Late-bound module attribute (not the function object): test spies
    # and user monkeypatches of e.g. matmul.cim_matmul_int must be seen
    # by dispatched executions too.
    def run(x_codes, w_codes, spec, *, key=None, planes=None, block=None):
        del block
        return getattr(module, attr)(
            x_codes, w_codes, spec, key=key, planes=planes
        )

    return run


def _ref_impl(module, attr: str) -> KernelFn:
    def run(x_codes, w_codes, spec, *, key=None, planes=None, block=None):
        del key, block  # noiseless vectorized formulation
        return getattr(module, attr)(x_codes, w_codes, spec, planes=planes)

    return run


def _pallas_blocks(
    spec: MacroSpec, block: tuple[int, int, int] | None
) -> tuple[int, int, int]:
    bm, bn, bk = block or (128, 128, 128)
    rows = spec.rows_active
    bk = max(rows, bk - bk % rows)  # kernel needs rows | bk
    return bm, bn, bk


def _slots_impl(attr: str) -> KernelFn:
    def run(x_codes, w_codes, spec, *, key=None, planes=None, slots=None,
            block=None):
        del w_codes, key, planes, block  # weight side IS the slot operand
        if slots is None:
            raise ValueError(
                "slots backend requires a plan's spread-slot operand "
                "grouped at the executing rows_active "
                "(engine.plan_weights(with_slots=True)); none provided"
            )
        return getattr(ref_lib, attr)(x_codes, slots, spec)

    return run


def _pallas_impl(kernel_name: str) -> KernelFn:
    def run(x_codes, w_codes, spec, *, key=None, planes=None, block=None):
        del key  # noiseless by design (production inference path)
        from repro.kernels import ops  # noqa: PLC0415 - optional pallas dep

        if planes is not None and planes.ndim == 3:
            # Packed plan planes [G, rows, N] uint8: bit b of each byte
            # is the weight's two's-complement bit b — exactly the
            # masked codes the kernel's in-tile unpack expects. The
            # flatten-slice recovers the [K, N] byte matrix at ANY
            # grouping (K-tail padding is all-zero bytes, dropped
            # here), so the resident int8 codes never re-load.
            k = x_codes.shape[1]
            w_codes = planes.reshape(-1, planes.shape[-1])[:k]
        bm, bn, bk = _pallas_blocks(spec, block)
        fn = getattr(ops, kernel_name)
        return fn(x_codes, w_codes, spec, bm=bm, bn=bn, bk=bk)

    return run


register_kernel(
    KernelKey("p8t", "scan"), _scan_impl(matmul_lib, "cim_matmul_int"),
    supports_noise=True, supports_planes=True,
)
register_kernel(
    KernelKey("p8t", "ref"), _ref_impl(ref_lib, "cim_matmul_ref"),
    supports_planes=True,
)
register_kernel(
    KernelKey("p8t", "slots"), _slots_impl("cim_matmul_slots"),
    supports_slots=True,
)
register_kernel(
    KernelKey("p8t", "pallas"), _pallas_impl("cim_matmul_kernel"),
    supports_planes=True, is_pallas=True,
)

# cell-adc: the ideal transfer equals the P-8T floor transfer, so scan
# and ref reuse those formulations; the Pallas kernel is the distinct
# per-row-reference SAR search (bit-identical codes).
register_kernel(
    KernelKey("cell-adc", "scan"), _scan_impl(matmul_lib, "cim_matmul_int"),
    supports_noise=True, supports_planes=True,
)
register_kernel(
    KernelKey("cell-adc", "ref"), _ref_impl(ref_lib, "cim_matmul_ref"),
    supports_planes=True,
)
register_kernel(
    KernelKey("cell-adc", "slots"), _slots_impl("cim_matmul_slots"),
    supports_slots=True,
)
register_kernel(
    KernelKey("cell-adc", "pallas"), _pallas_impl("cell_adc_matmul_kernel"),
    supports_planes=True, is_pallas=True,
)

register_kernel(
    KernelKey("adder-tree", "scan"),
    _scan_impl(variants_lib, "adder_tree_matmul_int"),
    supports_noise=True, supports_planes=True,
)
register_kernel(
    KernelKey("adder-tree", "ref"),
    _ref_impl(ref_lib, "adder_tree_matmul_ref"),
    supports_planes=True,
)
register_kernel(
    KernelKey("adder-tree", "slots"),
    _slots_impl("adder_tree_matmul_slots"),
    supports_slots=True,
)
register_kernel(
    KernelKey("adder-tree", "pallas"),
    _pallas_impl("adder_tree_matmul_kernel"),
    supports_planes=True, is_pallas=True,
)
