"""Checkpointing: msgpack + zstd/zlib tensor store, async writes,
elastic load.

Layout:
  <dir>/step_<n>/manifest.msgpack   -- tree structure + tensor metadata
                                       (+ "compression" format tag)
  <dir>/step_<n>/data.bin.zst       -- concatenated tensor payloads
  <dir>/LATEST                      -- atomic pointer (text, step number)

``zstandard`` is an optional dependency: when absent, writes fall back
to stdlib zlib (tagged in the manifest) and zstd-tagged checkpoints
raise a clear error on read. Either codec round-trips bit-exactly.

Design points for 1000+-node operation:
  * atomic publish: payload is fully written + fsynced before LATEST is
    flipped, so a crash mid-write never corrupts the restore point;
  * async: `save_async` snapshots device arrays to host (blocking only
    for the device->host copy) and writes in a background thread --
    training continues during serialization;
  * elastic reshard-on-load: tensors are stored unsharded (logical
    shapes); `restore` accepts a pytree of target shardings and
    device_puts each tensor under the *new* mesh, so a checkpoint
    written on one topology restores onto any topology whose sharding
    divides the shapes (tested);
  * in a real multi-host deployment each host writes its addressable
    shards; this container is single-process, so the tensor store
    writes full arrays -- the publish/rename protocol is identical.
"""

from __future__ import annotations

import os
import pathlib
import threading
import zlib
from typing import Any, Callable

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:  # optional dep: zstd is faster/denser, zlib is always there
    import zstandard
except ImportError:  # pragma: no cover - exercised on minimal installs
    zstandard = None

_KEY_SEP = "/"

# Payload codec, recorded in the manifest so readers never guess.
# Checkpoints written before the tag existed were always zstd.
_DEFAULT_COMPRESSION = "zstd" if zstandard is not None else "zlib"


class _ZlibWriter:
    """Streaming zlib writer with the zstd stream_writer surface."""

    def __init__(self, f, level: int):
        self._f = f
        self._comp = zlib.compressobj(level)

    def write(self, data: bytes) -> None:
        self._f.write(self._comp.compress(data))

    def finish(self) -> None:
        self._f.write(self._comp.flush())


def _open_writer(f, compression: str):
    if compression == "zstd":
        cctx = zstandard.ZstdCompressor(level=3)
        writer = cctx.stream_writer(f)
        return writer, lambda: writer.flush(zstandard.FLUSH_FRAME)
    if compression == "zlib":
        writer = _ZlibWriter(f, level=3)
        return writer, writer.finish
    raise ValueError(f"unknown compression '{compression}'")


def _decompress(blob: bytes, compression: str, max_output_size: int):
    if compression == "zstd":
        if zstandard is None:
            raise ModuleNotFoundError(
                "checkpoint payload is zstd-compressed but the optional "
                "'zstandard' package is not installed (pip install "
                "zstandard, or re-write the checkpoint on a host that "
                "has it)"
            )
        dctx = zstandard.ZstdDecompressor()
        return dctx.decompress(blob, max_output_size=max_output_size)
    if compression == "zlib":
        # Mirror the zstd path's bound: a corrupt/tampered payload must
        # fail instead of allocating unboundedly.
        d = zlib.decompressobj()
        out = d.decompress(blob, max_output_size)
        if d.unconsumed_tail:
            raise ValueError(
                "zlib checkpoint payload exceeds the manifest's "
                f"declared size ({max_output_size} bytes)"
            )
        return out
    raise ValueError(f"unknown compression '{compression}'")


def _np_dtype(name: str) -> np.dtype:
    """Resolve dtype names numpy doesn't know natively (bfloat16...)."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, name))


def _path_name(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):  # DictKey
            parts.append(str(p.key))
        elif hasattr(p, "name"):  # GetAttrKey: registered dataclasses
            # (engine.PlannedWeights, resnet.PlannedConv) flatten with
            # attribute paths; str(GetAttrKey) is ".field" — strip the
            # dot so planned-tree tensor names stay flat ("w/codes").
            parts.append(str(p.name))
        elif hasattr(p, "idx"):  # SequenceKey
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return _KEY_SEP.join(parts)


def _leaf_names(tree: Any) -> list[str]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [_path_name(path) for path, _ in flat]


def _flatten_with_paths(tree: Any) -> list[tuple[str, np.ndarray]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(_path_name(path), np.asarray(leaf)) for path, leaf in flat]


def save(tree: Any, directory: str | os.PathLike, step: int) -> str:
    """Synchronous checkpoint write with atomic publish."""
    directory = pathlib.Path(directory)
    final = directory / f"step_{step:08d}"
    tmp = directory / f".tmp_step_{step:08d}"
    tmp.mkdir(parents=True, exist_ok=True)

    entries = _flatten_with_paths(tree)
    compression = _DEFAULT_COMPRESSION
    manifest = []
    offset = 0
    # Filename kept for format continuity even under the zlib fallback;
    # the manifest's "compression" tag is authoritative.
    with open(tmp / "data.bin.zst", "wb") as f:
        writer, finish = _open_writer(f, compression)
        for name, arr in entries:
            raw = np.ascontiguousarray(arr).tobytes()
            writer.write(raw)
            manifest.append(
                {
                    "name": name,
                    "dtype": str(arr.dtype),
                    "shape": list(arr.shape),
                    "offset": offset,
                    "nbytes": len(raw),
                }
            )
            offset += len(raw)
        finish()
        f.flush()
        os.fsync(f.fileno())
    with open(tmp / "manifest.msgpack", "wb") as f:
        f.write(msgpack.packb({
            "step": step,
            "compression": compression,
            "tensors": manifest,
        }))
        f.flush()
        os.fsync(f.fileno())

    if final.exists():
        import shutil

        shutil.rmtree(final)
    tmp.rename(final)
    # Atomic LATEST flip.
    latest_tmp = directory / ".LATEST.tmp"
    latest_tmp.write_text(str(step))
    latest_tmp.rename(directory / "LATEST")
    return str(final)


class AsyncCheckpointer:
    """Snapshot-to-host then write in a daemon thread."""

    def __init__(self):
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def save(self, tree: Any, directory: str | os.PathLike, step: int):
        self.wait()  # one outstanding write at a time
        host_tree = jax.tree.map(np.asarray, tree)  # device->host copy

        def work():
            try:
                save(host_tree, directory, step)
            except BaseException as e:  # noqa: BLE001 - surfaced in wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err


def latest_step(directory: str | os.PathLike) -> int | None:
    f = pathlib.Path(directory) / "LATEST"
    if not f.exists():
        return None
    return int(f.read_text().strip())


def restore(
    directory: str | os.PathLike,
    target: Any,
    *,
    step: int | None = None,
    sharding_fn: Callable[[str, np.ndarray], Any] | None = None,
) -> Any:
    """Restore into the structure of `target` (pytree of arrays or
    ShapeDtypeStructs). `sharding_fn(name, arr)` may return a Sharding
    for elastic reshard-on-load; None -> plain device_put.
    """
    directory = pathlib.Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no LATEST in {directory}")
    d = directory / f"step_{step:08d}"
    meta = msgpack.unpackb((d / "manifest.msgpack").read_bytes())
    blob = _decompress(
        (d / "data.bin.zst").read_bytes(),
        meta.get("compression", "zstd"),  # pre-tag checkpoints: zstd
        max_output_size=sum(t["nbytes"] for t in meta["tensors"]) or 1,
    )
    by_name = {}
    for t in meta["tensors"]:
        # count must be explicit: frombuffer(count=-1) reads to the END
        # of the blob and requires global alignment -- mixed-dtype
        # trees (bf16 next to f32) break it.
        n = int(np.prod(t["shape"])) if t["shape"] else 1
        arr = np.frombuffer(
            blob, dtype=_np_dtype(t["dtype"]), count=n,
            offset=t["offset"],
        )
        by_name[t["name"]] = arr.reshape(t["shape"])

    names = _leaf_names(target)
    leaves, treedef = jax.tree.flatten(target)
    out = []
    for name, leaf in zip(names, leaves, strict=True):
        if name not in by_name:
            raise KeyError(f"checkpoint missing tensor '{name}'")
        arr = by_name[name]
        # python-scalar leaves (e.g. a step counter) have no shape/dtype
        want_shape = tuple(getattr(leaf, "shape", ()))
        if tuple(arr.shape) != want_shape:
            raise ValueError(
                f"{name}: checkpoint shape {arr.shape} != target "
                f"{want_shape}"
            )
        dtype = getattr(leaf, "dtype", None)
        if dtype is not None and arr.dtype != dtype:
            arr = arr.astype(dtype)
        if sharding_fn is not None:
            out.append(jax.device_put(arr, sharding_fn(name, arr)))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree.unflatten(treedef, out)
