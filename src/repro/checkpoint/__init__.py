"""Checkpoint substrate: atomic msgpack+zstd store, async, elastic."""

from repro.checkpoint.store import (
    AsyncCheckpointer,
    latest_step,
    restore,
    save,
)

__all__ = ["AsyncCheckpointer", "latest_step", "restore", "save"]
