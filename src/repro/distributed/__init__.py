"""Distribution: logical-axis sharding rules + activation constraints."""

from repro.distributed.sharding import (
    DEFAULT_RULES,
    batch_axes,
    cache_axes,
    constrain,
    constrain_query,
    replicated,
    sharding_for,
    spec_for,
    tree_shardings,
)

__all__ = [
    "DEFAULT_RULES",
    "batch_axes",
    "cache_axes",
    "constrain",
    "constrain_query",
    "replicated",
    "sharding_for",
    "spec_for",
    "tree_shardings",
]
