"""Logical-axis sharding rules (MaxText-style, one source of truth).

Every parameter/cache/batch tensor carries a tuple of *logical* axis
names (assigned in the model specs); this module maps them onto mesh
axes with divisibility-aware fallback:

  vocab/heads/kv_heads/mlp -> 'model'   (tensor parallel)
  embed                    -> 'data'    (FSDP: weights sharded over DP)
  batch                    -> ('pod', 'data')
  cache_seq                -> ('pod', 'data')  (sequence-parallel KV for
                              batch=1 long-context decode; only applies
                              when 'batch' could not use those axes)
  experts/layers           -> unsharded (EP is TP-within-expert; layers
                              is the scan dim)

A mesh axis is consumed at most once per tensor; a logical axis whose
dim is not divisible by the mesh axis size silently degrades to
replicated (e.g. whisper's 6 kv-heads on a 16-wide model axis), which
GSPMD then propagates -- correctness never depends on the rule table.
"""

from __future__ import annotations

import re
from typing import Any, Mapping

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "embed": ("data",),
    "experts": (),
    "layers": (),
    "batch": ("pod", "data"),
    "cache_seq": ("pod", "data"),
    "seq": (),
}

# Inference (prefill/decode) parameter rules: weights stay *stationary*
# (TP over 'model' only; replicated over 'data'), because FSDP-style
# 'embed'-over-data sharding forces a full-parameter all-gather every
# step -- measured +16 GiB temp on qwen1.5-4b decode. MoE expert banks
# are instead expert-parallel over 'data' (jamba's 700 GB of experts
# cannot replicate 16x).
INFERENCE_RULES: dict[str, tuple[str, ...]] = {
    **DEFAULT_RULES,
    "embed": (),
    "experts": ("data",),
}


def spec_for(
    axes: tuple[str | None, ...],
    shape: tuple[int, ...],
    mesh: Mesh,
    rules: Mapping[str, tuple[str, ...]] | None = None,
) -> PartitionSpec:
    """PartitionSpec for one tensor, divisibility-aware, no axis reuse."""
    rules = DEFAULT_RULES if rules is None else rules
    used: set[str] = set()
    entries: list[Any] = []
    for dim, ax in zip(shape, axes, strict=False):
        if ax is None or ax not in rules:
            entries.append(None)
            continue
        assigned: list[str] = []
        factor = 1
        for mesh_ax in rules[ax]:
            if mesh_ax in used or mesh_ax not in mesh.shape:
                continue
            size = mesh.shape[mesh_ax]
            if dim % (factor * size) == 0:
                assigned.append(mesh_ax)
                used.add(mesh_ax)
                factor *= size
        if not assigned:
            entries.append(None)
        elif len(assigned) == 1:
            entries.append(assigned[0])
        else:
            entries.append(tuple(assigned))
    return PartitionSpec(*entries)


def sharding_for(
    axes: tuple[str | None, ...],
    shape: tuple[int, ...],
    mesh: Mesh | None,
    rules: Mapping[str, tuple[str, ...]] | None = None,
) -> NamedSharding | None:
    if mesh is None:  # probe/unsharded path
        return None
    return NamedSharding(mesh, spec_for(axes, shape, mesh, rules))


def tree_shardings(
    axes_tree: Any,
    shape_tree: Any,
    mesh: Mesh | None,
    rules: Mapping[str, tuple[str, ...]] | None = None,
) -> Any:
    """Map matching (axes, ShapeDtypeStruct) trees -> NamedSharding tree."""
    if mesh is None:
        return None
    is_axes = lambda x: isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x
    )
    return jax.tree.map(
        lambda ax, sds: sharding_for(ax, tuple(sds.shape), mesh, rules),
        axes_tree,
        shape_tree,
        is_leaf=is_axes,
    )


# ---------------------------------------------------------------------------
# Name-based axes for caches and batches (leaf-name conventions)
# ---------------------------------------------------------------------------

_CACHE_AXES = {
    "k": ("batch", "cache_seq", "kv_heads", None),
    "v": ("batch", "cache_seq", "kv_heads", None),
    "conv": ("batch", None, "mlp"),
    "ssm": ("batch", "mlp", None),
    "state": ("batch", "heads", None, None),
    "shift_tm": ("batch", None),
    "shift_cm": ("batch", None),
}

_BATCH_AXES = {
    "tokens": ("batch", "seq"),
    "labels": ("batch", "seq"),
    "frontend_embeds": ("batch", None, None),
    "encoder_frames": ("batch", None, None),
    "image": ("batch", None, None, None),
    "label": ("batch",),
}


def _leaf_name(path) -> str:
    for p in reversed(path):
        if hasattr(p, "key"):
            return str(p.key)
        if hasattr(p, "name"):
            return str(p.name)
    return ""


def cache_axes(cache_tree: Any) -> Any:
    """Logical axes for a cache pytree by leaf-name convention.

    Caches stacked under a scanned 'units' group gain a leading
    'layers' axis (detected by ndim excess).
    """

    def one(path, leaf):
        name = _leaf_name(path)
        base = _CACHE_AXES.get(name)
        if base is None:
            raise KeyError(f"unknown cache leaf '{name}'")
        if len(leaf.shape) == len(base) + 1:
            return ("layers",) + base
        assert len(leaf.shape) == len(base), (name, leaf.shape)
        return base

    return jax.tree_util.tree_map_with_path(one, cache_tree)


def batch_axes(batch: Any) -> Any:
    def one(path, leaf):
        name = _leaf_name(path)
        base = _BATCH_AXES.get(name)
        if base is None:
            base = ("batch",) + (None,) * (len(leaf.shape) - 1)
        return base

    return jax.tree_util.tree_map_with_path(one, batch)


def _greedy_axes(
    dim: int, candidates: tuple[str, ...], mesh: Mesh, used: set[str]
) -> list[str]:
    got: list[str] = []
    factor = 1
    for ax in candidates:
        if ax in used or ax not in mesh.shape:
            continue
        size = mesh.shape[ax]
        if dim % (factor * size) == 0:
            got.append(ax)
            used.add(ax)
            factor *= size
    return got


def _entry(axs: list[str]):
    if not axs:
        return None
    return axs[0] if len(axs) == 1 else tuple(axs)


def kv_cache_spec(shape: tuple[int, ...], mesh: Mesh) -> PartitionSpec:
    """KV cache [(layers,) B, S, KVH, hd] with cross-dim fallback.

    Priority: batch <- (pod, data); kv_heads <- model; seq <- whatever
    mesh axes remain. The fallback is what makes decode cells fit HBM
    for archs whose kv-head count does not divide the model axis
    (qwen1.5: 20 kv-heads, yi-34b: 8) -- the 32k/500k cache then shards
    its *sequence* dim over the idle axes instead of replicating
    terabytes. GSPMD turns attention over a seq-sharded cache into
    partial-softmax + small reductions (the scores tensor, not the
    cache, crosses the links).
    """
    lead = len(shape) - 4
    b, s, kvh, _ = shape[lead:]
    used: set[str] = set()
    b_ax = _greedy_axes(b, ("pod", "data"), mesh, used)
    h_ax = _greedy_axes(kvh, ("model",), mesh, used)
    s_ax = _greedy_axes(s, ("model", "pod", "data"), mesh, used)
    return PartitionSpec(
        *((None,) * lead), _entry(b_ax), _entry(s_ax), _entry(h_ax), None
    )


def cache_shardings(cache_tree: Any, mesh: Mesh | None) -> Any:
    """NamedShardings for a serving-cache pytree.

    k/v leaves get the cross-dim-fallback spec above; SSM/RWKV state
    leaves go through the generic rule table (their dims are O(1) in
    seq, so the generic table suffices).
    """
    if mesh is None:
        return None

    def one(path, leaf):
        name = _leaf_name(path)
        shape = tuple(leaf.shape)
        if name in ("k", "v"):
            return NamedSharding(mesh, kv_cache_spec(shape, mesh))
        base = _CACHE_AXES[name]
        if len(shape) == len(base) + 1:
            base = ("layers",) + base
        return sharding_for(base, shape, mesh)

    return jax.tree_util.tree_map_with_path(one, cache_tree)


# ---------------------------------------------------------------------------
# Planned-weight (PlannedWeights) sharding: plan-aware serving
# ---------------------------------------------------------------------------


def _last_dim_model(shape: tuple[int, ...], mesh: Mesh) -> NamedSharding:
    """Shard the trailing (output-channel) dim over 'model' if divisible."""
    axs = _greedy_axes(shape[-1], ("model",), mesh, set())
    return NamedSharding(
        mesh, PartitionSpec(*((None,) * (len(shape) - 1)), _entry(axs))
    )


def plan_shardings(plan: Any, mesh: Mesh) -> Any:
    """NamedShardings for one ``engine.PlannedWeights``.

    Every stored-weight tensor is tensor-parallel over the model axis
    on its output-channel (N) dim — codes [..., K, N], kept fp weights,
    the [..., 1, N] epilogue vectors, and the pre-grouped ``planes`` in
    BOTH storage forms (unpacked [G, B, rows, N] int8 and bit-packed
    [G, rows, N] uint8): the group/plane/row dims are the contraction
    structure and must stay local to a shard, while N is embarrassingly
    parallel — each model shard holds the planes of its own output
    columns, so planned decode scales across devices without
    re-planning (divisibility-aware: an indivisible N degrades to
    replicated, like every rule here).
    """
    import dataclasses as _dc

    def one(v):
        return None if v is None else _last_dim_model(tuple(v.shape), mesh)

    return _dc.replace(
        plan,
        codes=one(plan.codes),
        scale=one(plan.scale),
        colsum=one(plan.colsum),
        w=one(plan.w),
        planes=one(plan.planes),
    )


def planned_param_shardings(
    planned_tree: Any, mesh: Mesh | None
) -> Any:
    """Shardings for a whole ``engine.plan_params`` tree.

    PlannedWeights leaves get :func:`plan_shardings`; unplanned leaves
    (norms, embeddings, biases) stay replicated — weight-stationary
    inference replicates them by design (see INFERENCE_RULES).
    """
    if mesh is None:
        return None
    from repro.core.engine import PlannedWeights  # lazy: keep import light

    def one(node):
        if isinstance(node, PlannedWeights):
            return plan_shardings(node, mesh)
        return replicated(mesh)

    return jax.tree.map(
        one, planned_tree,
        is_leaf=lambda x: isinstance(x, PlannedWeights),
    )


def shard_planned(planned_tree: Any, mesh: Mesh | None) -> Any:
    """device_put a planned tree under :func:`planned_param_shardings`."""
    if mesh is None:
        return planned_tree
    return jax.device_put(
        planned_tree, planned_param_shardings(planned_tree, mesh)
    )


def opt_state_axes(param_axes: Any, opt_state) -> Any:
    """AdamW m/v inherit the param axes; step/rng are replicated."""
    from repro.optim.adamw import AdamWState

    return AdamWState(
        step=(),
        m=param_axes,
        v=jax.tree.map(lambda a: a, param_axes),
    )


def replicated(mesh: Mesh | None) -> NamedSharding | None:
    if mesh is None:
        return None
    return NamedSharding(mesh, PartitionSpec())


# ---------------------------------------------------------------------------
# Activation sharding constraints (annotations inside model code)
# ---------------------------------------------------------------------------

_ACT_RULES: dict[str, tuple[str, ...]] = {
    "act_batch": ("pod", "data"),
    "act_seq": (),
    "act_vocab": ("model",),
    "act_heads": ("model",),
    "act_mlp": ("model",),
    "act_embed": (),
}


def constrain(x, axes: tuple[str | None, ...]):
    """with_sharding_constraint by logical activation axes.

    No-op when no mesh context is active (probe/smoke paths) or when a
    dim is not divisible by its mesh axes. Model code calls this at the
    few propagation cliffs (logits, embed output, FFN hidden) -- the
    MaxText pattern.
    """
    mesh = _ctx_mesh()
    if mesh is None:
        return x
    entries = []
    for dim, ax in zip(x.shape, axes, strict=False):
        names = []
        factor = 1
        if ax is not None:
            for mesh_ax in _ACT_RULES.get(ax, ()):
                if mesh_ax not in mesh.shape:
                    continue
                size = mesh.shape[mesh_ax]
                if dim % (factor * size) == 0:
                    names.append(mesh_ax)
                    factor *= size
        if not names:
            entries.append(None)
        elif len(names) == 1:
            entries.append(names[0])
        else:
            entries.append(tuple(names))
    try:
        return jax.lax.with_sharding_constraint(
            x, PartitionSpec(*entries)
        )
    except (ValueError, RuntimeError):
        return x


def _ctx_mesh():
    # jax < 0.5 has no get_abstract_mesh; only the legacy `with mesh:`
    # thread-resource context below exists there.
    get_abstract_mesh = getattr(jax.sharding, "get_abstract_mesh", None)
    mesh = get_abstract_mesh() if get_abstract_mesh is not None else None
    if mesh is not None and not mesh.empty and mesh.shape:
        return mesh
    try:  # legacy `with mesh:` context
        from jax._src import mesh as _mesh_lib  # noqa: PLC0415

        mesh = _mesh_lib.thread_resources.env.physical_mesh
    except Exception:  # noqa: BLE001
        return None
    if mesh is None or mesh.empty or not mesh.shape:
        return None
    return mesh


def constrain_query(q):
    """Shard the query tensor [B, S, H, hd] for the attention core.

    Priority: heads (H) on 'model' (tensor parallel); query-seq (S)
    fallback (context parallel) for archs whose head counts don't
    divide the model axis (qwen2-0.5b: 14 heads on a 16-wide axis).
    Constraining q (one producer) instead of the score tensor lets the
    SPMD solver pick consistent dot strategies downstream.
    """
    mesh = _ctx_mesh()
    if mesh is None:
        return q
    b, s, h, _ = q.shape
    batch_axes = []
    factor = 1
    for ax in ("pod", "data"):
        if ax in mesh.shape and b % (factor * mesh.shape[ax]) == 0:
            batch_axes.append(ax)
            factor *= mesh.shape[ax]
    bspec = (
        None if not batch_axes
        else batch_axes[0] if len(batch_axes) == 1
        else tuple(batch_axes)
    )
    model = mesh.shape.get("model", 1)
    spec = [bspec, None, None, None]
    if model > 1:
        if h % model == 0:
            spec[2] = "model"
        elif s % model == 0:
            spec[1] = "model"
    try:
        return jax.lax.with_sharding_constraint(q, PartitionSpec(*spec))
    except (ValueError, RuntimeError):
        return q
