"""Training substrate: jitted step factory + fault-tolerant driver."""

from repro.train.trainer import (
    StragglerWatchdog,
    Trainer,
    TrainerConfig,
    TrainState,
    init_train_state,
    make_train_step,
)

__all__ = [
    "StragglerWatchdog",
    "Trainer",
    "TrainerConfig",
    "TrainState",
    "init_train_state",
    "make_train_step",
]
