"""Training loop: jitted train_step factory + fault-tolerant driver.

make_train_step builds the full step (loss -> grad -> [compress] ->
clip -> AdamW) as one jitted, donated function; under a mesh the same
function is pjit-sharded by the in/out shardings from
repro.distributed.sharding. Microbatch gradient accumulation happens
*inside* the step (lax.scan over microbatches) so the HLO exposes the
accumulate-then-reduce structure XLA needs to overlap FSDP collectives
with compute.

The Trainer driver adds the 1000+-node operational pieces that live
above XLA: periodic async checkpoints, resume, a straggler watchdog
(EMA wall-time; slow-shard re-issue through the loader) and clean
abort/restart semantics (see tests/test_fault_tolerance.py).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.checkpoint import store
from repro.optim import adamw


class TrainState(NamedTuple):
    params: Any
    opt: adamw.AdamWState
    comp: adamw.CompressionState | None
    rng: jax.Array


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    checkpoint_dir: str = ""
    checkpoint_every: int = 50
    log_every: int = 10
    microbatches: int = 1  # gradient-accumulation factor
    compress_grads: bool = False
    # straggler watchdog
    straggler_factor: float = 3.0  # flag steps slower than f x EMA
    straggler_ema: float = 0.9


def init_train_state(
    key: jax.Array, params: Any, *, compress: bool = False
) -> TrainState:
    return TrainState(
        params=params,
        opt=adamw.init_state(params),
        comp=adamw.init_compression(params) if compress else None,
        rng=key,
    )


def make_train_step(
    loss_fn: Callable[..., tuple[jax.Array, dict]],
    opt_cfg: adamw.OptimizerConfig,
    *,
    microbatches: int = 1,
    accum_dtype=jnp.float32,
    compress: bool = False,
    donate: bool = True,
    jit: bool = True,
):
    """loss_fn(params, batch, key) -> (loss, metrics dict of scalars).

    jit=False returns the raw step function (the dry-run lowers it with
    explicit in/out shardings instead).
    """

    def step(state: TrainState, batch: Any) -> tuple[TrainState, dict]:
        key, new_rng = jax.random.split(state.rng)
        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

        if microbatches > 1:
            # batch leaves are [mb * b, ...] -> [mb, b, ...]; accumulate.
            def resh(x):
                return x.reshape((microbatches, -1) + x.shape[1:])

            mb_batch = jax.tree.map(resh, batch)

            def acc_body(carry, mb):
                g_acc, loss_acc = carry
                (loss, metrics), g = grad_fn(state.params, mb, key)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(a.dtype), g_acc, g
                )
                return (g_acc, loss_acc + loss), metrics

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), state.params
            )
            (g_sum, loss_sum), metrics = jax.lax.scan(
                acc_body, (g0, jnp.zeros((), jnp.float32)), mb_batch
            )
            grads = jax.tree.map(lambda g: g / microbatches, g_sum)
            loss = loss_sum / microbatches
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        else:
            (loss, metrics), grads = grad_fn(state.params, batch, key)

        comp = state.comp
        cmetrics = {}
        if compress and comp is not None:
            grads, comp, cmetrics = adamw.compress_decompress(grads, comp)

        params, opt, ometrics = adamw.apply_updates(
            state.params, grads, state.opt, opt_cfg
        )
        out_metrics = {"loss": loss, **metrics, **ometrics, **cmetrics}
        return TrainState(params, opt, comp, new_rng), out_metrics

    if not jit:
        return step
    return jax.jit(step, donate_argnums=(0,) if donate else ())


class StragglerWatchdog:
    """EMA wall-time monitor; reports shards that should be re-issued.

    In a single-process container there is no real peer host, so the
    watchdog's *policy* (detection + re-issue decision) is what we run
    and test; the RPC layer it would drive is a deployment concern.
    """

    def __init__(self, cfg: TrainerConfig, n_shards: int = 1):
        self.cfg = cfg
        self.ema: float | None = None
        self.flagged: list[tuple[int, int, float]] = []
        self.n_shards = n_shards

    def observe(self, step: int, seconds: float,
                shard_times: dict[int, float] | None = None) -> list[int]:
        """Returns shard ids to re-issue (empty in the common case)."""
        slow: list[int] = []
        if self.ema is None:
            self.ema = seconds
        limit = self.cfg.straggler_factor * self.ema
        if shard_times:
            for shard, t in shard_times.items():
                if t > limit:
                    slow.append(shard)
                    self.flagged.append((step, shard, t))
        elif seconds > limit:
            self.flagged.append((step, -1, seconds))
        a = self.cfg.straggler_ema
        self.ema = a * self.ema + (1 - a) * seconds
        return slow


class Trainer:
    def __init__(
        self,
        train_step,
        state: TrainState,
        loader,
        cfg: TrainerConfig,
    ):
        self.train_step = train_step
        self.state = state
        self.loader = loader
        self.cfg = cfg
        self.step = 0
        self.watchdog = StragglerWatchdog(cfg)
        self.ckpt = store.AsyncCheckpointer()
        self.history: list[dict] = []

    def maybe_resume(self) -> int:
        """Restore the latest checkpoint if one exists; returns step."""
        if not self.cfg.checkpoint_dir:
            return 0
        last = store.latest_step(self.cfg.checkpoint_dir)
        if last is None:
            return 0
        payload = store.restore(
            self.cfg.checkpoint_dir,
            {"state": self.state, "step": 0},
            step=last,
        )
        self.state = payload["state"]
        self.step = int(payload["step"])
        return self.step

    def run(self, n_steps: int, *, abort_at: int | None = None):
        """Train; abort_at simulates a node failure mid-run (tests)."""
        target = self.step + n_steps
        for step_id, batch in self.loader:
            if self.step >= target:
                break
            t0 = time.monotonic()
            self.state, metrics = self.train_step(self.state, batch)
            loss = float(metrics["loss"])  # forces device sync
            dt = time.monotonic() - t0
            for shard in self.watchdog.observe(self.step, dt):
                self.loader.reissue(step_id, shard)
            self.step += 1
            if self.step % self.cfg.log_every == 0 or self.step == target:
                self.history.append(
                    {"step": self.step, "loss": loss, "sec": dt}
                )
            if (
                self.cfg.checkpoint_dir
                and self.step % self.cfg.checkpoint_every == 0
            ):
                self.ckpt.save(
                    {"state": self.state, "step": self.step},
                    self.cfg.checkpoint_dir,
                    self.step,
                )
            if abort_at is not None and self.step >= abort_at:
                self.ckpt.wait()
                raise RuntimeError(f"simulated failure at step {self.step}")
        self.ckpt.wait()
        return self.history

    def planned_params(self, policy=None):
        """Weight-stationary export of the current params for serving.

        Runs core.engine.plan_params over the live training params:
        the train->serve handoff that turns per-step QAT weights into
        the precomputed codes/colsums/scales ServeEngine reuses across
        every decode step. policy=None exports the digital int8
        weight-only form.
        """
        from repro.core import engine as cim_engine

        return cim_engine.plan_params(self.state.params, policy=policy)

    def final_checkpoint(self):
        if self.cfg.checkpoint_dir:
            self.ckpt.save(
                {"state": self.state, "step": self.step},
                self.cfg.checkpoint_dir,
                self.step,
            )
            self.ckpt.wait()
