"""Batched serving example (deliverable b): continuous batching over a
fixed decode batch, KV/state caches, CIM-executed weight matmuls.

Five requests of different lengths share two decode slots; finished
slots are refilled mid-flight. Runs the rwkv6 (attention-free, O(1)
state) and qwen2 (GQA KV cache) smoke backbones, fp vs cim-exact.

  PYTHONPATH=src python examples/serve_cim.py
"""

import time

import jax
import numpy as np

from repro.configs.base import CIMPolicy, get_config
from repro.core.params import PAPER_OP_16ROWS
from repro.models import transformer
from repro.serve.engine import ContinuousBatcher, Request, ServeEngine


def demo(arch: str, mode: str):
    cfg = get_config(arch, smoke=True)
    if mode != "fp":
        cfg = cfg.replace(cim=CIMPolicy(mode=mode, cim=PAPER_OP_16ROWS))
    params = transformer.init(jax.random.PRNGKey(0), cfg)
    # plan=True precomputes the weight-stationary CIM state once
    # (core.engine.plan_params); every decode step then skips the
    # weight-side quantize/colsum work. Tokens are bit-identical to
    # the unplanned engine under CIM modes.
    engine = ServeEngine(params, cfg, max_len=96, batch=2,
                         plan=(mode != "fp"))
    batcher = ContinuousBatcher(engine, eos_token=-1)

    rng = np.random.default_rng(0)
    for rid, (plen, gen) in enumerate([(4, 6), (8, 4), (3, 8), (6, 5),
                                       (5, 7)]):
        batcher.submit(Request(
            rid=rid, prompt=rng.integers(0, cfg.vocab_size, plen),
            max_new=gen))
    t0 = time.time()
    done = batcher.run_until_done()
    dt = time.time() - t0
    toks = sum(len(r.generated) for r in done)
    print(f"{arch:12s} mode={mode:9s} {len(done)} requests, "
          f"{toks} tokens in {dt:.1f}s")
    for r in sorted(done, key=lambda r: r.rid):
        print(f"  req{r.rid}: prompt[{len(r.prompt)}] -> {r.generated}")


def main():
    for arch in ("qwen2_0_5b", "rwkv6_1_6b"):
        for mode in ("fp", "cim-exact"):
            demo(arch, mode)
    print("\nContinuous batching: requests 2..4 were admitted into slots "
          "freed by earlier completions (one shared decode step).")


if __name__ == "__main__":
    main()
