"""The paper's Sec. IV hardware-aware system analysis as a runnable
study (deliverable b): train a ResNet on the synthetic-CIFAR task, then
co-design {activated rows, cutoff, ADC bits} under hardware errors --
the loop that picked the paper's {8/16 rows, cutoff 0.5, 4-bit ADC}
operating point.

  PYTHONPATH=src:. python examples/cim_accuracy_study.py [--fast]
"""

import argparse
import dataclasses

import jax.numpy as jnp

from benchmarks.common import (
    RESNET_CFG, cim_policy, evaluate, train_resnet_baseline,
)
from repro.configs.base import CIMPolicy
from repro.core import calibrate_resnet
from repro.core.calibrate import CalibrationGrid
from repro.sweep import analyze, load_config
from repro.sweep import runner as sweep_runner
from repro.sweep.config import REPO_ROOT

SWEEP_CONFIGS = REPO_ROOT / "configs" / "sweeps"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    n_images = 128 if args.fast else 256

    print("training fp32 ResNet baseline on synthetic-CIFAR ...")
    params, bn, ds = train_resnet_baseline()
    fp = evaluate(params, bn, ds, CIMPolicy(mode="fp"),
                  n_images=n_images)
    print(f"fp32 baseline accuracy: {fp:.3f} "
          "(paper: 92.34% on CIFAR-10)\n")

    print("=== cutoff sweep @ 16 rows, 4-bit ADC (paper Fig. 7a) ===")
    for noisy in (False, True):
        row = []
        for cutoff in (0.25, 0.5, 0.75):
            acc = evaluate(params, bn, ds,
                           cim_policy(cutoff=cutoff, noisy=noisy),
                           n_images=n_images)
            row.append(f"cutoff {cutoff}: {acc:.3f}")
        tag = "w/ HW errors " if noisy else "ideal        "
        print(f"  {tag}" + "  ".join(row))

    print("\n=== rows x ADC bits @ cutoff 0.5, HW errors (Fig. 7b) ===")
    # The same table as a declarative sweep: the committed config
    # expands to the rows x bits grid, runs resumably (append-only
    # points.jsonl; re-running the example skips completed points) and
    # the analysis pass renders the summary table.
    # Overriding a param changes the config hash (a different study),
    # so the non-default profile gets its own results dir.
    fig7b = load_config(SWEEP_CONFIGS / "accuracy_study.json")
    if n_images != fig7b.params["n_images"]:
        fig7b = fig7b.override(
            params={"n_images": n_images},
            out_dir=f"results/sweeps/accuracy_study_n{n_images}",
        )
    sweep_runner.run(fig7b)
    for path in analyze(fig7b):
        print(f"  wrote {path}")
    recs = sorted(sweep_runner.read_points(fig7b).values(),
                  key=lambda r: r["index"])
    for rows in (4, 8, 16):
        cells = [
            f"{r['point']['adc_bits']}b: {r['result']['accuracy']:.3f}"
            for r in recs
            if r["status"] == "ok" and r["point"]["rows_active"] == rows
        ]
        print(f"  {rows:2d} rows  " + "  ".join(cells))

    print("\n=== the paper's operating point (Table I) ===")
    for rows in (8, 16):
        for noisy in (False, True):
            acc = evaluate(params, bn, ds,
                           cim_policy(rows=rows, noisy=noisy),
                           n_images=n_images)
            tag = "w/ HW" if noisy else "ideal"
            print(f"  {rows:2d} rows {tag}: {acc:.3f} "
                  f"(drop {fp-acc:+.3f})")
    print("\n=== hardware-aware per-layer calibration (core.calibrate) ===")
    # The sweep the tables above run by hand, as one API call: per
    # conv layer, pick the cheapest (adc_bits, rows, coarse/fine split)
    # within the fidelity slack, then execute the whole network through
    # the calibrated specs via the registered "analog" backend.
    pol = cim_policy(noisy=True)
    rcfg = dataclasses.replace(RESNET_CFG, cim=pol)
    images = jnp.asarray(ds.batch(64, step=0, train=False)["image"])
    result = calibrate_resnet(params, bn, images, rcfg,
                              max_samples=128 if args.fast else 256)
    print(result.summary())
    result.register("analog")
    acc = evaluate(params, bn, ds,
                   dataclasses.replace(pol, backend="analog"),
                   n_images=n_images)
    print(f"accuracy with per-layer calibrated 'analog' backend: "
          f"{acc:.3f} (drop {fp-acc:+.3f})")

    print("\n=== macro-variant axis (core.variants) ===")
    # Re-run the sweep letting each layer choose its macro family too:
    # the paper's P-8T flash vs the single-ADC analog adder network
    # (arXiv:2212.04320) vs the memory cell-embedded ADC
    # (arXiv:2307.05944). The summary's variant/TOPS/W columns show
    # what the joint fidelity-vs-cost rule picks per layer.
    vres = calibrate_resnet(
        params, bn, images, rcfg,
        grid=CalibrationGrid(
            variants=("p8t", "adder-tree", "cell-adc")),
        max_samples=128 if args.fast else 256,
    )
    print(vres.summary())
    vres.register("analog-variants")
    acc_v = evaluate(params, bn, ds,
                     dataclasses.replace(pol, backend="analog-variants"),
                     n_images=n_images)
    print(f"accuracy with variant-calibrated backend: {acc_v:.3f} "
          f"(drop {fp-acc_v:+.3f})")

    print("\n=== accuracy-driven refinement + variants x vdd pareto ===")
    # Phase two of the co-design, as the committed sweep config: the
    # measure re-sweeps with the vdd axis (cost becomes J/op via the
    # energy model), greedily refines against REAL held-out top-1
    # accuracy — each candidate eval is a full forward through
    # engine.execute / kernels.dispatch — and each grid point is one
    # (variant, vdd) projection of the refined plan. The analysis pass
    # renders the per-model accuracy-vs-TOPS/W frontier.
    study = load_config(SWEEP_CONFIGS / "resnet_study.json")
    if not args.fast:
        study = study.override(
            params={"rows_active": [8, 16], "budget": 12,
                    "max_samples": 256, "n_cal": 256, "n_held": 64},
            out_dir="results/sweeps/resnet_study_full",
        )
    sweep_runner.run(study)
    jpath, mpath = analyze(study)
    print(mpath.read_text())
    print(f"(written to {jpath} and {mpath})")

    print("\nExpected orderings (the paper's claims): accuracy falls "
          "with more active rows under noise; 4-bit ADC ~ 5-bit under "
          "noise; cutoff 0.5 costs <~1-2% vs fp; the calibration sweep "
          "lands on the paper's 4-bit/16-row operating point; "
          "refinement never regresses TOPS/W and holds held-out top-1 "
          "within tolerance.")


if __name__ == "__main__":
    main()
