"""The paper's Sec. IV hardware-aware system analysis as a runnable
study (deliverable b): train a ResNet on the synthetic-CIFAR task, then
co-design {activated rows, cutoff, ADC bits} under hardware errors --
the loop that picked the paper's {8/16 rows, cutoff 0.5, 4-bit ADC}
operating point.

  PYTHONPATH=src:. python examples/cim_accuracy_study.py [--fast]
"""

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import (
    RESNET_CFG, cim_policy, evaluate, train_resnet_baseline,
)
from benchmarks.pareto import markdown_table, report_dict, write_report
from repro.configs.base import CIMPolicy
from repro.core import calibrate_resnet
from repro.core.calibrate import (
    CalibrationGrid, refine, resnet_eval_fn,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    n_images = 128 if args.fast else 256

    print("training fp32 ResNet baseline on synthetic-CIFAR ...")
    params, bn, ds = train_resnet_baseline()
    fp = evaluate(params, bn, ds, CIMPolicy(mode="fp"),
                  n_images=n_images)
    print(f"fp32 baseline accuracy: {fp:.3f} "
          "(paper: 92.34% on CIFAR-10)\n")

    print("=== cutoff sweep @ 16 rows, 4-bit ADC (paper Fig. 7a) ===")
    for noisy in (False, True):
        row = []
        for cutoff in (0.25, 0.5, 0.75):
            acc = evaluate(params, bn, ds,
                           cim_policy(cutoff=cutoff, noisy=noisy),
                           n_images=n_images)
            row.append(f"cutoff {cutoff}: {acc:.3f}")
        tag = "w/ HW errors " if noisy else "ideal        "
        print(f"  {tag}" + "  ".join(row))

    print("\n=== rows x ADC bits @ cutoff 0.5, HW errors (Fig. 7b) ===")
    for rows in (4, 8, 16):
        row = []
        for bits in (3, 4, 5):
            acc = evaluate(
                params, bn, ds,
                cim_policy(rows=rows, adc_bits=bits, noisy=True),
                n_images=n_images)
            row.append(f"{bits}b: {acc:.3f}")
        print(f"  {rows:2d} rows  " + "  ".join(row))

    print("\n=== the paper's operating point (Table I) ===")
    for rows in (8, 16):
        for noisy in (False, True):
            acc = evaluate(params, bn, ds,
                           cim_policy(rows=rows, noisy=noisy),
                           n_images=n_images)
            tag = "w/ HW" if noisy else "ideal"
            print(f"  {rows:2d} rows {tag}: {acc:.3f} "
                  f"(drop {fp-acc:+.3f})")
    print("\n=== hardware-aware per-layer calibration (core.calibrate) ===")
    # The sweep the tables above run by hand, as one API call: per
    # conv layer, pick the cheapest (adc_bits, rows, coarse/fine split)
    # within the fidelity slack, then execute the whole network through
    # the calibrated specs via the registered "analog" backend.
    pol = cim_policy(noisy=True)
    rcfg = dataclasses.replace(RESNET_CFG, cim=pol)
    images = jnp.asarray(ds.batch(64, step=0, train=False)["image"])
    result = calibrate_resnet(params, bn, images, rcfg,
                              max_samples=128 if args.fast else 256)
    print(result.summary())
    result.register("analog")
    acc = evaluate(params, bn, ds,
                   dataclasses.replace(pol, backend="analog"),
                   n_images=n_images)
    print(f"accuracy with per-layer calibrated 'analog' backend: "
          f"{acc:.3f} (drop {fp-acc:+.3f})")

    print("\n=== macro-variant axis (core.variants) ===")
    # Re-run the sweep letting each layer choose its macro family too:
    # the paper's P-8T flash vs the single-ADC analog adder network
    # (arXiv:2212.04320) vs the memory cell-embedded ADC
    # (arXiv:2307.05944). The summary's variant/TOPS/W columns show
    # what the joint fidelity-vs-cost rule picks per layer.
    vres = calibrate_resnet(
        params, bn, images, rcfg,
        grid=CalibrationGrid(
            variants=("p8t", "adder-tree", "cell-adc")),
        max_samples=128 if args.fast else 256,
    )
    print(vres.summary())
    vres.register("analog-variants")
    acc_v = evaluate(params, bn, ds,
                     dataclasses.replace(pol, backend="analog-variants"),
                     n_images=n_images)
    print(f"accuracy with variant-calibrated backend: {acc_v:.3f} "
          f"(drop {fp-acc_v:+.3f})")

    print("\n=== accuracy-driven refinement + variants x vdd pareto ===")
    # Phase two of the co-design: re-sweep with cutoff/vdd axes (cost
    # becomes J/op via the energy model), then greedily refine against
    # REAL held-out top-1 accuracy — each candidate eval is a full
    # forward through engine.execute / kernels.dispatch — and report
    # the per-model accuracy-vs-TOPS/W frontier across variants x vdd.
    vdd_grid = CalibrationGrid(
        variants=("p8t", "adder-tree", "cell-adc"),
        rows_active=(16,) if args.fast else (8, 16),
        coarse_bits=(1,),
        vdd=(0.6, 0.9, 1.2),
    )
    eres = calibrate_resnet(params, bn, images, rcfg, grid=vdd_grid,
                            max_samples=128 if args.fast else 256)
    # Each candidate eval is an eager end-to-end forward over the
    # held-out batch; evals are memoized per supply-stripped plan, so
    # the budget bounds the wall time directly.
    held = ds.batch(32 if args.fast else 64, step=7, train=False)
    eval_fn = resnet_eval_fn(
        params, bn, jnp.asarray(held["image"]), held["label"], rcfg,
        key=jax.random.PRNGKey(1),
    )
    refined = refine(eres, eval_fn, budget=4 if args.fast else 12,
                     tol=0.01)
    print(refined.summary())
    print(f"effective TOPS/W: seed {eres.effective_tops_per_w():.2f} "
          f"-> refined {refined.effective_tops_per_w():.2f}")
    points = refined.pareto(eval_fn=eval_fn)
    jpath, mpath = write_report("resnet_study", refined, points)
    print(markdown_table(report_dict("resnet_study", refined, points)))
    print(f"(written to {jpath} and {mpath})")

    print("\nExpected orderings (the paper's claims): accuracy falls "
          "with more active rows under noise; 4-bit ADC ~ 5-bit under "
          "noise; cutoff 0.5 costs <~1-2% vs fp; the calibration sweep "
          "lands on the paper's 4-bit/16-row operating point; "
          "refinement never regresses TOPS/W and holds held-out top-1 "
          "within tolerance.")


if __name__ == "__main__":
    main()
