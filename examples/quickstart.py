"""Quickstart: the P-8T SRAM CIM macro as a JAX matmul execution mode.

Runs in seconds on CPU:
  1. one voltage-domain macro op (the faithful circuit model),
  2. the same computation as an integer GPQ matmul + Pallas kernel,
  3. a CIM-executed linear layer inside a tiny transformer,
  4. the weight-stationary plan/execute split (docs/api.md),
  5. the paper's operating-point numbers from the energy model.

Usage: PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import CIMPolicy
from repro.core import (
    PAPER_OP_16ROWS,
    adc_transfer_int,
    cim_matmul,
    cim_matmul_exact_int,
    cim_matmul_int,
    engine,
    macro_op,
    macro_report,
)
from repro.kernels.ops import cim_matmul_kernel

key = jax.random.PRNGKey(0)
cfg = PAPER_OP_16ROWS
print(f"operating point: {cfg.rows_active} rows, cutoff {cfg.cutoff}, "
      f"{cfg.adc_bits}-bit coarse-fine ADC, threshold {cfg.threshold} "
      f"of {cfg.pmac_levels} pMAC levels, step {cfg.adc_step}")

# ---- 1. one macro cycle in the voltage domain --------------------------
x16 = jax.random.randint(key, (16,), 0, 16)  # 16 4-bit activations
w16 = jax.random.randint(key, (16, 8), -128, 128)  # 8 output channels
out = macro_op(x16, w16, cfg)
print("\nvoltage-domain macro op")
print("  ABL voltages (col 0, 8 bit-planes):",
      np.round(np.asarray(out.v_abl[0]), 4))
print("  ADC codes   (col 0):", np.asarray(out.adc_codes[0]))
print("  shift-add outputs:", np.asarray(out.outputs, np.int64))
print("  exact int result :",
      np.asarray(x16 @ w16, np.int64))

# ---- 2. GPQ matmul: behavioral scan vs Pallas kernel -------------------
xm = jax.random.randint(key, (8, 64), 0, 16)
wm = jax.random.randint(jax.random.fold_in(key, 1), (64, 8), -128, 128)
y_scan = cim_matmul_int(xm, wm, cfg)
y_kernel = cim_matmul_kernel(xm, wm, cfg, bm=8, bn=8, bk=32)
y_exact = cim_matmul_exact_int(xm, wm)
print("\nGPQ matmul [8,64]x[64,8]")
print(f"  scan == kernel: {np.allclose(y_scan, y_kernel)}")
print(f"  mean |ADC quantization error| vs exact: "
      f"{float(jnp.mean(jnp.abs(y_scan - y_exact))):.2f} "
      f"(ADC step {cfg.adc_step})")

# ---- 3. a CIM-executed linear layer on float data ----------------------
# Post-ReLU activations (the paper's CNN setting, act_symmetric=True).
x = jax.nn.relu(jax.random.normal(key, (32, 128)))
w = 0.1 * jax.random.normal(jax.random.fold_in(key, 2), (128, 32))
y_fp = x @ w
y_exact = cim_matmul(x, w, cfg, mode="cim-exact", act_symmetric=True)
y_cim = cim_matmul(x, w, cfg, mode="cim", act_symmetric=True)
rel_e = float(jnp.linalg.norm(y_exact - y_fp) / jnp.linalg.norm(y_fp))
rel_c = float(jnp.linalg.norm(y_cim - y_fp) / jnp.linalg.norm(y_fp))
print("\nfloat linear layer through the macro (quant + ADC + dequant)")
print(f"  4b-act/8b-weight quantization alone : {rel_e:.1%} rel err")
print(f"  + per-16-row-group 4-bit ADC        : {rel_c:.1%} rel err")
print("  (the ADC term dominates -- exactly why the paper co-designs "
      "{rows, cutoff, ADC bits} against accuracy; networks absorb it "
      "to ~1% top-1, see benchmarks/table1_accuracy.py)")

# gradients flow through the macro (STE) -> QAT-ready
g = jax.grad(lambda w: jnp.sum(
    cim_matmul(x, w, cfg, mode='cim', act_symmetric=True) ** 2))(w)
print(f"  STE gradient norm: {float(jnp.linalg.norm(g)):.3f}")

# ---- 4. weight-stationary plan/execute (the serving hot path) ----------
# The macro stores weights once and reuses them per input; the API
# mirrors that: plan_weights once, execute per batch. Bit-exact with
# the one-shot call above, minus all per-call weight-side work.
policy = CIMPolicy(mode="cim", cim=cfg, act_symmetric=True)
plan = engine.plan_weights(w, cfg, policy)  # codes+colsum+planes, once
y_planned = engine.execute(x, plan, policy)
x_next = jax.nn.relu(jax.random.normal(jax.random.fold_in(key, 3),
                                       (32, 128)))
y_next = engine.execute(x_next, plan, policy)  # plan reused
print("\nweight-stationary plan/execute")
print(f"  planned == one-shot: {bool(jnp.array_equal(y_planned, y_cim))}")
print(f"  plan storage: codes {plan.codes.dtype}, grouped planes "
      f"{plan.planes.dtype}{list(plan.planes.shape)} [G,B,rows,N], "
      f"backends {engine.backend_names()}")
print(f"  second batch through same plan: {y_next.shape}")

# ---- 5. the paper's headline numbers -----------------------------------
print("\nanalytical macro model (28nm anchors)")
for vdd in (0.6, 0.9, 1.2):
    rep = macro_report(cfg.replace(vdd=vdd))
    print(f"  {vdd:.1f} V: {rep.tops_per_w:6.2f} TOPS/W, "
          f"{rep.freq_mhz:5.1f} MHz")
print("\n(Paper: 50.07 TOPS/W @ 0.6 V, 9.77 @ 1.2 V, "
      "accuracy 91.46% CIFAR-10 @ 8 rows.)")
