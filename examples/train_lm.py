"""End-to-end training driver (deliverable b): train a small LM for a
few hundred steps on CPU with the full substrate -- sharded loader,
AdamW + schedule, periodic async checkpoints, crash-safe resume -- and
compare fp training against CIM-QAT (training *through* the macro model
with STE), the LM analogue of the paper's hardware-aware simulations.

  PYTHONPATH=src python examples/train_lm.py            # ~5 min CPU
  PYTHONPATH=src python examples/train_lm.py --steps 300 --cim

The model is the qwen2-family block at a ~6M-param scale (the substrate
is identical to the full configs; only dims shrink).
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import CIMPolicy, get_config
from repro.core.params import PAPER_OP_16ROWS
from repro.data import MarkovLM, ShardedLoader
from repro.models import transformer
from repro.optim import OptimizerConfig
from repro.train import Trainer, TrainerConfig, init_train_state, \
    make_train_step


def build_cfg(cim: bool):
    cfg = get_config("qwen2_0_5b", smoke=True).replace(
        n_layers=4, d_model=256, n_heads=8, n_kv_heads=4, d_ff=1024,
        # vocab 64 with deterministic order-2 transitions (branching 1)
        # gives a 4k-entry table a 5M model memorizes in a few hundred
        # CPU steps: loss floor 0, unigram ~ ln(64) = 4.16.
        vocab_size=64, activation_dtype="float32",
    )
    if cim:
        cfg = cfg.replace(
            cim=CIMPolicy(mode="cim", cim=PAPER_OP_16ROWS,
                          apply_to_logits=False))
    return cfg


def run(cfg, steps, batch, seq, ckpt_dir, label):
    key = jax.random.PRNGKey(0)
    params = transformer.init(key, cfg)
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))

    def loss(p, b, k):
        return transformer.loss_fn(p, b, cfg, key=k)

    step_fn = make_train_step(
        loss,
        OptimizerConfig(lr=3e-3, total_steps=steps,
                        warmup_steps=max(steps // 20, 1)),
    )
    lm = MarkovLM(cfg.vocab_size, seed=0, branching=1)
    loader = ShardedLoader(
        lambda s, sh, ns: {k: jnp.asarray(v) for k, v in
                           lm.batch(batch, seq, s, shard=sh,
                                    n_shards=ns).items()})
    trainer = Trainer(step_fn, init_train_state(key, params), loader,
                      TrainerConfig(checkpoint_dir=ckpt_dir,
                                    checkpoint_every=100, log_every=20))
    resumed = trainer.maybe_resume()
    if resumed:
        print(f"[{label}] resumed from step {resumed}")
    t0 = time.time()
    hist = trainer.run(steps - resumed)
    trainer.final_checkpoint()
    loader.close()
    dt = time.time() - t0
    first, last = hist[0]["loss"], hist[-1]["loss"]
    print(f"[{label}] params={n/1e6:.2f}M steps={steps} "
          f"loss {first:.3f} -> {last:.3f} "
          f"({batch*seq*len(hist)*20/dt:.0f} tok/s)")
    for h in hist:
        print(f"[{label}] step {h['step']:4d} loss {h['loss']:.4f}")
    return last


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--cim", action="store_true",
                    help="also run CIM-QAT (slower: macro sim forward)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    fp_loss = run(build_cfg(cim=False), args.steps, args.batch, args.seq,
                  args.ckpt_dir + "_fp", "fp")
    if args.cim:
        cim_loss = run(build_cfg(cim=True), max(args.steps // 4, 30),
                       args.batch, args.seq, args.ckpt_dir + "_cim",
                       "cim-qat")
        print(f"\nfp final loss {fp_loss:.3f}; cim-qat (fewer steps) "
              f"{cim_loss:.3f} -- training *through* the ADC transfer "
              "converges (STE), the paper's co-design loop at LM scale.")


if __name__ == "__main__":
    main()
